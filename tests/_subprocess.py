"""Shared subprocess harness for the end-to-end suites.

`test_fault_tolerance.py` and `test_dist_solve.py` each grew their own
copy of the same env dance — scrub inherited `XLA_FLAGS` (a pytest session
forced to 8 host devices must not leak its simulated topology into
subprocess experiments that pick their own), put `src` on the import path,
run with a hard timeout. One copy drifting from the other is how flaky
suites are born, so both now route through here. Failures report the
child's FULL captured stdout/stderr via `pytest.fail` — a dead subprocess
with swallowed output is undebuggable in CI.
"""
from __future__ import annotations

import os
import subprocess
import sys

import pytest


def scrubbed_env(device_count=None, **extra) -> dict:
    """A copy of the environment safe for repro subprocesses: inherited
    `XLA_FLAGS` dropped (or replaced by an explicit forced host-device
    count), `src` prepended to PYTHONPATH, `extra` overlaid last."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    if device_count is not None:
        env["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={device_count}")
    prior = env.get("PYTHONPATH")
    env["PYTHONPATH"] = "src" + (os.pathsep + prior if prior else "")
    env.update(extra)
    return env


def run_python(args=(), *, snippet: str = None, device_count=None,
               timeout: float = 600, check: bool = True, env_extra=None):
    """Run `python <args>` (or `python -c snippet`) under `scrubbed_env`.

    Timeouts and non-zero exits (with `check`) become `pytest.fail` with
    the child's captured output attached; returns the CompletedProcess
    otherwise so callers can make their own stdout assertions.
    """
    cmd = [sys.executable] + (["-c", snippet] if snippet is not None
                              else list(args))
    env = scrubbed_env(device_count, **(env_extra or {}))
    try:
        r = subprocess.run(cmd, cwd=os.getcwd(), env=env,
                           capture_output=True, text=True, timeout=timeout)
    except subprocess.TimeoutExpired as e:
        pytest.fail(f"subprocess exceeded the {timeout}s hard timeout: "
                    f"{cmd}\n--- stdout ---\n{e.stdout}\n"
                    f"--- stderr ---\n{e.stderr}")
    if check and r.returncode != 0:
        pytest.fail(f"subprocess failed (rc={r.returncode}): {cmd}\n"
                    f"--- stdout ---\n{r.stdout}\n"
                    f"--- stderr ---\n{r.stderr}")
    return r
